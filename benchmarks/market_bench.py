"""Live-market benchmarks: incremental reprice + the selection daemon.

    PYTHONPATH=src python benchmarks/market_bench.py

Four claims are enforced (ISSUE 2/4/5 acceptance — the script exits
nonzero if a gated claim regresses, which is the CI gate):

  * incremental ``RankState.reprice`` beats a full ``rank_dense`` by >=5x
    at 10k configs with <=1% of prices changed per tick, with rankings
    **bit-identical** to the cold path (exact float equality, not approx).
    The gated comparison is the per-tick update (what ``SelectionService``
    pays per tick — rankings materialize lazily on the next submission);
    the ``+materialize`` row reports the tick+first-submission end-to-end
    cost, where building/sorting the C ``RankedConfig`` objects dominates
    *both* paths equally and compresses the ratio;
  * the accelerator-resident jitted delta kernel (``JaxRankState``) beats
    a cold ``rank_dense(backend="jax")`` per tick while staying inside
    the jax ``ScoreContract`` (``reprice_jax_*`` rows);
  * one batched dispatch reprices a whole fleet of >=8 live rankings
    (``reprice_batched_*`` rows: ``one_dispatch_per_tick`` +
    ``within_contract`` gates, DESIGN.md §10);
  * the fused Pallas delta-rank kernel (``jax_pallas``, DESIGN.md §14)
    reprices the fleet in ONE ``pallas_call`` per tick within the same
    contract, head-to-head against the XLA delta path
    (``reprice_pallas_*`` rows: ``one_dispatch_per_tick`` +
    ``within_contract`` gates; the speed column is informational — on
    CPU the kernel runs ``interpret=True``);
  * device-side top-k serving beats the PR-4 materialize path end-to-end
    by >=3x at 64x10k (``topk_serve_*`` rows: the ``end_to_end_speedup``
    gate — one dispatch plus an O(k) readback versus per-state dispatches
    plus a full C-config host sort);
  * the device-sharded fleet (``jax_sharded``, DESIGN.md §13) spends one
    *collective* shard_map dispatch per tick and, on 8 devices at 100k
    configs, beats the single-device batched fleet
    (``reprice_sharded_*`` rows: ``one_dispatch_per_tick`` +
    ``within_contract`` + ``beats_single_device`` gates; the 8-device
    row needs ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on
    a CPU host and emits ``skipped=...`` elsewhere);
  * ``SelectionDaemon`` sustains a 10k-event mixed submission/tick stream
    deterministically — the same seed yields a byte-identical journal.

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows as
machine-readable ``BENCH_market.json`` (override the path with the
``BENCH_MARKET_JSON`` env var) so CI can track the perf trajectory.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from _bench_io import BenchRows, Gates, check_gates
from repro.core.trace import JobClass
from repro.market import SelectionDaemon, SimulatedSpotFeed, synthetic_stream
from repro.selector import (BatchedRankState, IdentityCatalog, JaxRankState,
                            PallasBatchedRankState, PriceTable,
                            ProfilingStore, RankState, SelectionService,
                            backend_available, rank_dense, score_contract)

ROWS = BenchRows("BENCH_MARKET_JSON", "BENCH_market.json")
emit = ROWS.emit
write_json = ROWS.write_json

#: gated claims that failed this run; main() exits nonzero on any.
GATES = Gates()
gate = GATES.gate


# --- incremental reprice vs full rank_dense ----------------------------------

def _universe(n_jobs: int, n_cfgs: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    hours = rng.uniform(0.05, 10.0, size=(n_jobs, n_cfgs))
    mask = rng.random((n_jobs, n_cfgs)) > 0.15        # partial profiling
    mask[np.arange(n_jobs), rng.integers(0, n_cfgs, n_jobs)] = True
    prices = rng.uniform(0.5, 20.0, size=n_cfgs)
    ids = [f"c{i}" for i in range(n_cfgs)]
    return hours, mask, prices, ids, rng


def _delta_batches(ids, prices, rng, n_ticks: int, frac: float):
    batches = []
    for _ in range(n_ticks):
        k = max(1, int(len(ids) * frac))
        cols = rng.choice(len(ids), k, replace=False)
        batches.append({ids[c]: float(prices[c] * rng.uniform(0.5, 2.0))
                        for c in cols})
    return batches


def bench_reprice(n_jobs: int, n_cfgs: int, frac: float,
                  n_ticks: int = 10) -> None:
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)

    # identity sweep (untimed): every tick bit-identical to the cold path
    state = RankState(hours, mask, prices, ids)
    live = prices.copy()
    identical = True
    for batch in batches:
        state.reprice(batch)
        for cid, p in batch.items():
            live[int(cid[1:])] = p
        cold = rank_dense(hours, mask, live, ids)
        inc = state.ranking()
        if [(r.config_id, r.score, r.mean_norm_cost) for r in cold] != \
                [(r.config_id, r.score, r.mean_norm_cost) for r in inc]:
            identical = False
            break

    # timed: the per-tick update (the service's tick cost; rankings
    # materialize lazily) vs a cold rank_dense per tick
    state = RankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
    us_reprice = (time.perf_counter() - t0) / n_ticks * 1e6
    t0 = time.perf_counter()
    for _ in batches:
        rank_dense(hours, mask, state.prices, ids)
    us_full = (time.perf_counter() - t0) / n_ticks * 1e6
    # end-to-end tick+submission: both paths build the RankedConfig list
    state = RankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
        state.ranking()
    us_e2e = (time.perf_counter() - t0) / n_ticks * 1e6

    speedup = us_full / us_reprice
    emit(f"reprice_{n_jobs}x{n_cfgs}_{frac:.0%}", us_reprice,
         f"cells={n_jobs * n_cfgs};full_rank_us={us_full:.1f};"
         f"speedup={speedup:.1f}x;target_5x={speedup >= 5.0};"
         f"bit_identical={identical}")
    emit(f"reprice_{n_jobs}x{n_cfgs}_{frac:.0%}+materialize", us_e2e,
         f"full_rank_us={us_full:.1f};"
         f"end_to_end_speedup={us_full / us_e2e:.1f}x;"
         f"materialize_us={us_e2e - us_reprice:.1f}")


# --- jax backend: resident delta kernel vs cold jax vs numpy ------------------

def bench_reprice_jax(n_jobs: int, n_cfgs: int, frac: float,
                      n_ticks: int = 10) -> None:
    """ISSUE 4 acceptance: the accelerator-resident jitted delta path
    must beat a cold ``rank_dense(backend="jax")`` per tick (which
    re-uploads the whole float64 universe and re-materializes the
    ranking), while staying inside the jax ``ScoreContract`` against a
    float64 numpy reference."""
    name = f"reprice_jax_{n_jobs}x{n_cfgs}_{frac:.0%}"
    if not backend_available("jax"):
        emit(name, 0.0, "skipped=jax_unavailable")
        return
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)
    contract = score_contract("jax")

    # contract sweep (untimed): winner + scores vs the float64 reference
    state = JaxRankState(hours, mask, prices, ids)
    ref = RankState(hours, mask, prices, ids)
    within = True
    for batch in batches:
        state.reprice(batch)
        ref.reprice(batch)
        cold = ref.ranking()
        by_id = {r.config_id: r.score for r in cold}
        jx = state.ranking()
        if not contract.winner_matches(jx[0].config_id, cold) or not all(
                contract.scores_match(r.score, by_id[r.config_id])
                for r in jx):
            within = False
            break

    # timed: the per-tick resident update (sync — reprice returns the
    # handoff count) vs a cold jax rank per tick; warm the jit caches
    # first so compile time is not billed to either side
    state = JaxRankState(hours, mask, prices, ids)
    state.reprice(batches[0])
    rank_dense(hours, mask, state.prices, ids, backend="jax")
    state = JaxRankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
    us_delta = (time.perf_counter() - t0) / n_ticks * 1e6
    live = state.prices
    t0 = time.perf_counter()
    for _ in batches:
        rank_dense(hours, mask, live, ids, backend="jax")
    us_cold = (time.perf_counter() - t0) / n_ticks * 1e6
    # end-to-end: tick + lazy materialization on the next submission
    state = JaxRankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
        state.ranking()
    us_e2e = (time.perf_counter() - t0) / n_ticks * 1e6

    emit(name, us_delta,
         f"cells={n_jobs * n_cfgs};jax_cold_us={us_cold:.1f};"
         f"speedup_vs_jax_cold={us_cold / us_delta:.1f}x;"
         f"beats_jax_cold={us_cold > us_delta};"
         f"within_contract={within};"
         f"contract=rel{contract.rel_tol:g}/abs{contract.abs_tol:g}")
    gate(name, "delta kernel beats cold jax rank per tick",
         us_cold > us_delta)
    gate(name, "within_contract", within)
    emit(f"{name}+materialize", us_e2e,
         f"jax_cold_us={us_cold:.1f};"
         f"end_to_end_speedup={us_cold / us_e2e:.1f}x;"
         f"materialize_us={us_e2e - us_delta:.1f}")


# --- batched fleet repricing + device-side top-k serving ----------------------

def _fleet_members(n_jobs: int, n_states: int, rng) -> "dict[str, list]":
    """Deterministic member row subsets (each a 30-90% slice of the job
    axis) standing in for live (class, exclusion) selections."""
    members = {}
    for s in range(n_states):
        size = max(2, int(n_jobs * rng.uniform(0.3, 0.9)))
        members[f"s{s}"] = sorted(
            int(i) for i in rng.choice(n_jobs, size, replace=False))
    return members


def _within_contract_vs_refs(batched, refs, members, contract) -> bool:
    """Vectorized contract check of every member against its float64
    incremental reference: all score accumulators inside the rel/abs
    envelope, and the batched winner's *cold* score tied to the cold
    best within the contract (the winner_matches discipline without
    materializing 10k RankedConfigs per member per tick)."""
    for key in members:
        ref = refs[key]
        b = batched.scores(key)
        r = ref.scores
        if not np.all(np.abs(b - r) <= contract.abs_tol
                      + contract.rel_tol * np.maximum(np.abs(b),
                                                      np.abs(r))):
            return False
        cold = np.where(ref.counts > 0, r, np.inf)
        w = batched.top_k(key, 1)[0]
        w_pos = batched.config_ids.index(w.config_id)
        if not contract.scores_match(float(cold[w_pos]),
                                     float(cold.min())):
            return False
    return True


def bench_reprice_batched(n_jobs: int, n_cfgs: int, frac: float,
                          n_states: int = 8, n_ticks: int = 10) -> None:
    """ISSUE 5 acceptance: one batched dispatch per tick reprices a
    fleet of >=8 live rankings (vs one dispatch per state on the PR-4
    path), within the jax_batched ``ScoreContract`` of per-state
    float64 references.  Gated: ``one_dispatch_per_tick`` +
    ``within_contract``."""
    name = f"reprice_batched_{n_jobs}x{n_cfgs}" + (
        "" if n_states == 8 else f"_{n_states}states")
    if not backend_available("jax_batched"):
        emit(name, 0.0, "skipped=jax_unavailable")
        return
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)
    members = _fleet_members(n_jobs, n_states, rng)
    contract = score_contract("jax_batched")

    # contract sweep (untimed): every member, every tick, vs the
    # float64 incremental references
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    refs = {key: RankState(hours[rows], mask[rows], prices.copy(), ids)
            for key, rows in members.items()}
    within = True
    for batch in batches:
        batched.reprice(batch)
        for ref in refs.values():
            ref.reprice(batch)
        if not _within_contract_vs_refs(batched, refs, members, contract):
            within = False
            break

    # timed: the whole fleet per tick — one batched dispatch vs one
    # JaxRankState dispatch per member (warm the jits first so compile
    # time is billed to neither side)
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    batched.reprice(batches[0])
    states = {key: JaxRankState(hours[rows], mask[rows], prices, ids)
              for key, rows in members.items()}
    for st in states.values():
        st.reprice(batches[0])
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    t0 = time.perf_counter()
    for batch in batches:
        batched.reprice(batch)
    us_batched = (time.perf_counter() - t0) / n_ticks * 1e6
    one_dispatch = batched.dispatches == n_ticks and \
        batched.n_active == n_states
    states = {key: JaxRankState(hours[rows], mask[rows], prices, ids)
              for key, rows in members.items()}
    t0 = time.perf_counter()
    for batch in batches:
        for st in states.values():
            st.reprice(batch)
    us_per_state = (time.perf_counter() - t0) / n_ticks * 1e6

    emit(name, us_batched,
         f"cells={n_jobs * n_cfgs};states={n_states};"
         f"dispatches_per_tick={batched.dispatches / n_ticks:.2f};"
         f"one_dispatch_per_tick={one_dispatch};"
         f"per_state_us={us_per_state:.1f};"
         f"speedup_vs_per_state={us_per_state / us_batched:.1f}x;"
         f"within_contract={within};"
         f"contract=rel{contract.rel_tol:g}/abs{contract.abs_tol:g}")
    gate(name, f"one dispatch per tick for >= {n_states} live states",
         one_dispatch)
    gate(name, "within_contract", within)


def bench_reprice_pallas(n_jobs: int, n_cfgs: int, frac: float,
                         n_states: int = 8, n_ticks: int = 10) -> None:
    """ISSUE 9 acceptance: the fused Pallas delta-rank kernel
    (``jax_pallas``, DESIGN.md §14) reprices the fleet in ONE
    ``pallas_call`` per tick, within the jax ``ScoreContract`` of
    per-member float64 references and head-to-head against the XLA
    delta path.  Gated: ``one_dispatch_per_tick`` + ``within_contract``
    (the speed column is informational — on CPU the kernel runs
    ``interpret=True``, so the honest perf reading needs TPU)."""
    name = f"reprice_pallas_{n_jobs}x{n_cfgs}" + (
        "" if n_states == 8 else f"_{n_states}states")
    if not backend_available("jax_pallas"):
        emit(name, 0.0, "skipped=jax_unavailable")
        return
    from repro.kernels.ops import _interpret
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)
    members = _fleet_members(n_jobs, n_states, rng)
    contract = score_contract("jax_pallas")

    # contract sweep (untimed): every member, every tick, vs the
    # float64 incremental references
    fused = PallasBatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        fused.add_state(key, rows=rows)
    refs = {key: RankState(hours[rows], mask[rows], prices.copy(), ids)
            for key, rows in members.items()}
    within = True
    for batch in batches:
        fused.reprice(batch)
        for ref in refs.values():
            ref.reprice(batch)
        if not _within_contract_vs_refs(fused, refs, members, contract):
            within = False
            break

    # timed head-to-head vs the XLA delta path (warm both jits first)
    fused = PallasBatchedRankState(hours, mask, prices, ids)
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        fused.add_state(key, rows=rows)
        batched.add_state(key, rows=rows)
    fused.reprice(batches[0])
    batched.reprice(batches[0])
    fused = PallasBatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        fused.add_state(key, rows=rows)
    t0 = time.perf_counter()
    for batch in batches:
        fused.reprice(batch)
    us_fused = (time.perf_counter() - t0) / n_ticks * 1e6
    one_dispatch = fused.dispatches == n_ticks and \
        fused.n_active == n_states
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    t0 = time.perf_counter()
    for batch in batches:
        batched.reprice(batch)
    us_xla = (time.perf_counter() - t0) / n_ticks * 1e6

    emit(name, us_fused,
         f"cells={n_jobs * n_cfgs};states={n_states};"
         f"dispatches_per_tick={fused.dispatches / n_ticks:.2f};"
         f"one_dispatch_per_tick={one_dispatch};"
         f"xla_delta_us={us_xla:.1f};"
         f"vs_xla_delta={us_xla / us_fused:.2f}x;"
         f"interpret={_interpret()};"
         f"within_contract={within};"
         f"contract=rel{contract.rel_tol:g}/abs{contract.abs_tol:g}")
    gate(name, f"one fused dispatch per tick for >= {n_states} live "
               f"states", one_dispatch)
    gate(name, "within_contract", within)


def bench_reprice_sharded(n_jobs: int, n_cfgs: int, frac: float,
                          n_states: int = 8, n_ticks: int = 10,
                          n_devices: "int | None" = None,
                          gate_speedup: bool = False) -> None:
    """ISSUE 8 acceptance: the device-sharded fleet (the C axis split
    over a 1-D mesh, DESIGN.md §13) spends one *collective* shard_map
    dispatch per tick for the whole fleet and, on 8 devices at >=100k
    configs, beats the single-device batched fleet per tick — within
    the jax_sharded ``ScoreContract`` of per-state float64 references.
    Gated: ``one_dispatch_per_tick`` + ``within_contract`` (+
    ``beats_single_device`` when ``gate_speedup``); rows needing more
    devices than the host exposes emit ``skipped=...`` instead of
    gating, so the claim is enforced only on the CI leg that forces an
    8-device host platform."""
    if not backend_available("jax_sharded"):
        emit(f"reprice_sharded_{n_devices or 1}x{n_cfgs}", 0.0,
             "skipped=jax_unavailable")
        return
    import jax

    from repro.selector import ShardedBatchedRankState
    avail = jax.device_count()
    n_dev = avail if n_devices is None else n_devices
    name = f"reprice_sharded_{n_dev}x{n_cfgs}"
    if n_dev > avail:
        emit(name, 0.0, f"skipped=needs_{n_dev}_devices_have_{avail}")
        return
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)
    members = _fleet_members(n_jobs, n_states, rng)
    contract = score_contract("jax_sharded")

    # contract sweep (untimed): every member vs its float64 incremental
    # reference; the 100k row trims the sweep to 3 ticks so the smoke
    # budget pays for the timed comparison, not the float64 re-ranks
    sweep = batches if n_cfgs < 100_000 else batches[:3]
    sharded = ShardedBatchedRankState(hours, mask, prices, ids,
                                      devices=n_dev)
    for key, rows in members.items():
        sharded.add_state(key, rows=rows)
    refs = {key: RankState(hours[rows], mask[rows], prices.copy(), ids)
            for key, rows in members.items()}
    within = True
    for batch in sweep:
        sharded.reprice(batch)
        for ref in refs.values():
            ref.reprice(batch)
        if not _within_contract_vs_refs(sharded, refs, members, contract):
            within = False
            break

    # timed: one collective sharded dispatch per tick vs the
    # single-device batched fleet (warm both jit caches first so
    # compile time is billed to neither side)
    sharded = ShardedBatchedRankState(hours, mask, prices, ids,
                                      devices=n_dev)
    for key, rows in members.items():
        sharded.add_state(key, rows=rows)
    sharded.reprice(batches[0])
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    batched.reprice(batches[0])

    sharded = ShardedBatchedRankState(hours, mask, prices, ids,
                                      devices=n_dev)
    for key, rows in members.items():
        sharded.add_state(key, rows=rows)
    t0 = time.perf_counter()
    for batch in batches:
        sharded.reprice(batch)
    us_sharded = (time.perf_counter() - t0) / n_ticks * 1e6
    one_dispatch = sharded.dispatches == n_ticks and \
        sharded.n_active == n_states
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    t0 = time.perf_counter()
    for batch in batches:
        batched.reprice(batch)
    us_single = (time.perf_counter() - t0) / n_ticks * 1e6

    speedup = us_single / us_sharded
    emit(name, us_sharded,
         f"cells={n_jobs * n_cfgs};states={n_states};devices={n_dev};"
         f"dispatches_per_tick={sharded.dispatches / n_ticks:.2f};"
         f"one_dispatch_per_tick={one_dispatch};"
         f"single_device_us={us_single:.1f};"
         f"speedup_vs_single_device={speedup:.2f}x;"
         f"beats_single_device={us_single > us_sharded};"
         f"within_contract={within};"
         f"contract=rel{contract.rel_tol:g}/abs{contract.abs_tol:g}")
    gate(name, "one collective dispatch per tick for the whole fleet",
         one_dispatch)
    gate(name, "within_contract", within)
    if gate_speedup:
        gate(name, f"{n_dev}-device sharded beats single-device batched "
                   f"at {n_cfgs} configs (got {speedup:.2f}x)",
             us_single > us_sharded)


def bench_topk_serve(n_jobs: int, n_cfgs: int, frac: float,
                     n_states: int = 8, k: int = 3,
                     n_ticks: int = 10) -> None:
    """ISSUE 5 acceptance: serving a tick + the head of one ranking via
    the batched kernel and device-side ``top_k`` beats the PR-4
    materialize path (per-state dispatches + a full C-config host
    materialize/sort on the next submission) by >=3x end-to-end.
    Gated: ``end_to_end_speedup`` — CI fails if it regresses below
    3x."""
    name = f"topk_serve_{n_jobs}x{n_cfgs}"
    if not backend_available("jax_batched"):
        emit(name, 0.0, "skipped=jax_unavailable")
        return
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)
    members = _fleet_members(n_jobs, n_states, rng)
    served = next(iter(members))

    # PR-4 path: per-state dispatches, then the served class
    # materializes+sorts its full ranking on the next submission
    states = {key: JaxRankState(hours[rows], mask[rows], prices, ids)
              for key, rows in members.items()}
    for st in states.values():
        st.reprice(batches[0])
    states[served].ranking()
    states = {key: JaxRankState(hours[rows], mask[rows], prices, ids)
              for key, rows in members.items()}
    t0 = time.perf_counter()
    for batch in batches:
        for st in states.values():
            st.reprice(batch)
        states[served].ranking()
    us_materialize = (time.perf_counter() - t0) / n_ticks * 1e6

    # the PR-5 path: one batched dispatch + an O(k) device head readback
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    batched.reprice(batches[0])
    batched.top_k(served, k)
    batched = BatchedRankState(hours, mask, prices, ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    t0 = time.perf_counter()
    for batch in batches:
        batched.reprice(batch)
        batched.top_k(served, k)
    us_topk = (time.perf_counter() - t0) / n_ticks * 1e6
    # head sanity (untimed): the served head IS the ranking's head
    head_ok = batched.top_k(served, k) == batched.ranking(served)[:k]

    speedup = us_materialize / us_topk
    emit(name, us_topk,
         f"cells={n_jobs * n_cfgs};states={n_states};k={k};"
         f"materialize_us={us_materialize:.1f};"
         f"end_to_end_speedup={speedup:.1f}x;"
         f"target_3x={speedup >= 3.0};head_matches={head_ok}")
    gate(name, f"end_to_end_speedup >= 3x (got {speedup:.1f}x)",
         speedup >= 3.0)
    gate(name, "top_k head matches materialized ranking", head_ok)


# --- the 10k-event daemon stream ---------------------------------------------

def _daemon(n_jobs: int = 24, n_cfgs: int = 128, seed: int = 7
            ) -> SelectionDaemon:
    rng = np.random.default_rng(seed)
    ids = [f"cfg{i}" for i in range(n_cfgs)]
    store = ProfilingStore(config_ids=ids)
    for j in range(n_jobs):
        klass = JobClass.A if j % 2 else JobClass.B
        for c in range(n_cfgs):
            if rng.random() < 0.2:
                continue                      # partial profiling
            store.add(f"job{j}", ids[c], float(rng.uniform(0.1, 5.0)),
                      job_class=klass, group=f"g{j % 6}")
    table = PriceTable({c: float(rng.uniform(1.0, 30.0)) for c in ids})
    service = SelectionService(IdentityCatalog(ids), store, table)
    feed = SimulatedSpotFeed(dict(table.items()), seed=seed,
                             change_fraction=0.01)
    return SelectionDaemon(service, feed)


def bench_daemon(n_events: int = 10_000, seed: int = 7) -> None:
    daemon = _daemon(seed=seed)
    jobs = daemon.service.store.job_ids
    t0 = time.perf_counter()
    stats = daemon.run(synthetic_stream(jobs, n_events, seed=seed))
    dt = time.perf_counter() - t0
    # determinism: a fresh universe + the same seed => byte-identical journal
    again = _daemon(seed=seed)
    again.run(synthetic_stream(jobs, n_events, seed=seed))
    deterministic = again.journal_dump() == daemon.journal_dump()
    svc = daemon.service
    hit_rate = svc.cache_hits / max(1, svc.cache_hits + svc.cache_misses)
    emit(f"daemon_{n_events}ev", dt / n_events * 1e6,
         f"events_per_s={n_events / dt:.0f};decisions={stats.decisions};"
         f"ticks={stats.ticks};epochs={stats.epochs};"
         f"deltas={stats.deltas};cache_hit_rate={hit_rate:.3f};"
         f"incremental_refreshes={svc.reprice_refreshes};"
         f"deterministic={deterministic}")


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    bench_reprice(64, 1_000, 0.01)
    bench_reprice(64, 10_000, 0.01)
    bench_reprice_jax(64, 10_000, 0.01)
    # the ISSUE 5/8/9 acceptance rows run in smoke mode too: CI gates
    # them (the pallas row's universe is sized for interpret mode on
    # CPU — the kernel replays its grid step-by-step there)
    bench_reprice_batched(64, 10_000, 0.01)
    bench_reprice_pallas(64, 2_000, 0.01)
    bench_topk_serve(64, 10_000, 0.01)
    # always-run small sharded row over whatever devices the host has,
    # plus the gated ISSUE 8 row (8 devices x 100k configs; emits a
    # skipped row — no gate — on hosts without 8 devices)
    bench_reprice_sharded(64, 10_000, 0.01)
    bench_reprice_sharded(64, 100_000, 0.01, n_devices=8,
                          gate_speedup=True)
    if not smoke:
        bench_reprice(64, 10_000, 0.001)
        bench_reprice(256, 10_000, 0.01)
        bench_reprice_jax(64, 10_000, 0.001)
        bench_reprice_batched(64, 10_000, 0.001, n_states=16)
        bench_reprice_pallas(64, 2_000, 0.001, n_states=16)
        bench_reprice_sharded(64, 10_000, 0.001, n_states=16)
    bench_daemon(2_000 if smoke else 10_000)
    write_json()
    check_gates(GATES.failures)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
