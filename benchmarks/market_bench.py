"""Live-market benchmarks: incremental reprice + the selection daemon.

    PYTHONPATH=src python benchmarks/market_bench.py

Two claims are enforced (ISSUE 2 acceptance):

  * incremental ``RankState.reprice`` beats a full ``rank_dense`` by >=5x
    at 10k configs with <=1% of prices changed per tick, with rankings
    **bit-identical** to the cold path (exact float equality, not approx).
    The gated comparison is the per-tick update (what ``SelectionService``
    pays per tick — rankings materialize lazily on the next submission);
    the ``+materialize`` row reports the tick+first-submission end-to-end
    cost, where building/sorting the C ``RankedConfig`` objects dominates
    *both* paths equally and compresses the ratio;
  * ``SelectionDaemon`` sustains a 10k-event mixed submission/tick stream
    deterministically — the same seed yields a byte-identical journal.

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows as
machine-readable ``BENCH_market.json`` (override the path with the
``BENCH_MARKET_JSON`` env var) so CI can track the perf trajectory.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from _bench_io import BenchRows
from repro.core.trace import JobClass
from repro.market import SelectionDaemon, SimulatedSpotFeed, synthetic_stream
from repro.selector import (IdentityCatalog, JaxRankState, PriceTable,
                            ProfilingStore, RankState, SelectionService,
                            backend_available, rank_dense, score_contract)

ROWS = BenchRows("BENCH_MARKET_JSON", "BENCH_market.json")
emit = ROWS.emit
write_json = ROWS.write_json


# --- incremental reprice vs full rank_dense ----------------------------------

def _universe(n_jobs: int, n_cfgs: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    hours = rng.uniform(0.05, 10.0, size=(n_jobs, n_cfgs))
    mask = rng.random((n_jobs, n_cfgs)) > 0.15        # partial profiling
    mask[np.arange(n_jobs), rng.integers(0, n_cfgs, n_jobs)] = True
    prices = rng.uniform(0.5, 20.0, size=n_cfgs)
    ids = [f"c{i}" for i in range(n_cfgs)]
    return hours, mask, prices, ids, rng


def _delta_batches(ids, prices, rng, n_ticks: int, frac: float):
    batches = []
    for _ in range(n_ticks):
        k = max(1, int(len(ids) * frac))
        cols = rng.choice(len(ids), k, replace=False)
        batches.append({ids[c]: float(prices[c] * rng.uniform(0.5, 2.0))
                        for c in cols})
    return batches


def bench_reprice(n_jobs: int, n_cfgs: int, frac: float,
                  n_ticks: int = 10) -> None:
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)

    # identity sweep (untimed): every tick bit-identical to the cold path
    state = RankState(hours, mask, prices, ids)
    live = prices.copy()
    identical = True
    for batch in batches:
        state.reprice(batch)
        for cid, p in batch.items():
            live[int(cid[1:])] = p
        cold = rank_dense(hours, mask, live, ids)
        inc = state.ranking()
        if [(r.config_id, r.score, r.mean_norm_cost) for r in cold] != \
                [(r.config_id, r.score, r.mean_norm_cost) for r in inc]:
            identical = False
            break

    # timed: the per-tick update (the service's tick cost; rankings
    # materialize lazily) vs a cold rank_dense per tick
    state = RankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
    us_reprice = (time.perf_counter() - t0) / n_ticks * 1e6
    t0 = time.perf_counter()
    for _ in batches:
        rank_dense(hours, mask, state.prices, ids)
    us_full = (time.perf_counter() - t0) / n_ticks * 1e6
    # end-to-end tick+submission: both paths build the RankedConfig list
    state = RankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
        state.ranking()
    us_e2e = (time.perf_counter() - t0) / n_ticks * 1e6

    speedup = us_full / us_reprice
    emit(f"reprice_{n_jobs}x{n_cfgs}_{frac:.0%}", us_reprice,
         f"cells={n_jobs * n_cfgs};full_rank_us={us_full:.1f};"
         f"speedup={speedup:.1f}x;target_5x={speedup >= 5.0};"
         f"bit_identical={identical}")
    emit(f"reprice_{n_jobs}x{n_cfgs}_{frac:.0%}+materialize", us_e2e,
         f"full_rank_us={us_full:.1f};"
         f"end_to_end_speedup={us_full / us_e2e:.1f}x;"
         f"materialize_us={us_e2e - us_reprice:.1f}")


# --- jax backend: resident delta kernel vs cold jax vs numpy ------------------

def bench_reprice_jax(n_jobs: int, n_cfgs: int, frac: float,
                      n_ticks: int = 10) -> None:
    """ISSUE 4 acceptance: the accelerator-resident jitted delta path
    must beat a cold ``rank_dense(backend="jax")`` per tick (which
    re-uploads the whole float64 universe and re-materializes the
    ranking), while staying inside the jax ``ScoreContract`` against a
    float64 numpy reference."""
    name = f"reprice_jax_{n_jobs}x{n_cfgs}_{frac:.0%}"
    if not backend_available("jax"):
        emit(name, 0.0, "skipped=jax_unavailable")
        return
    hours, mask, prices, ids, rng = _universe(n_jobs, n_cfgs)
    batches = _delta_batches(ids, prices, rng, n_ticks, frac)
    contract = score_contract("jax")

    # contract sweep (untimed): winner + scores vs the float64 reference
    state = JaxRankState(hours, mask, prices, ids)
    ref = RankState(hours, mask, prices, ids)
    within = True
    for batch in batches:
        state.reprice(batch)
        ref.reprice(batch)
        cold = ref.ranking()
        by_id = {r.config_id: r.score for r in cold}
        jx = state.ranking()
        if not contract.winner_matches(jx[0].config_id, cold) or not all(
                contract.scores_match(r.score, by_id[r.config_id])
                for r in jx):
            within = False
            break

    # timed: the per-tick resident update (sync — reprice returns the
    # handoff count) vs a cold jax rank per tick; warm the jit caches
    # first so compile time is not billed to either side
    state = JaxRankState(hours, mask, prices, ids)
    state.reprice(batches[0])
    rank_dense(hours, mask, state.prices, ids, backend="jax")
    state = JaxRankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
    us_delta = (time.perf_counter() - t0) / n_ticks * 1e6
    live = state.prices
    t0 = time.perf_counter()
    for _ in batches:
        rank_dense(hours, mask, live, ids, backend="jax")
    us_cold = (time.perf_counter() - t0) / n_ticks * 1e6
    # end-to-end: tick + lazy materialization on the next submission
    state = JaxRankState(hours, mask, prices, ids)
    t0 = time.perf_counter()
    for batch in batches:
        state.reprice(batch)
        state.ranking()
    us_e2e = (time.perf_counter() - t0) / n_ticks * 1e6

    emit(name, us_delta,
         f"cells={n_jobs * n_cfgs};jax_cold_us={us_cold:.1f};"
         f"speedup_vs_jax_cold={us_cold / us_delta:.1f}x;"
         f"beats_jax_cold={us_cold > us_delta};"
         f"within_contract={within};"
         f"contract=rel{contract.rel_tol:g}/abs{contract.abs_tol:g}")
    emit(f"{name}+materialize", us_e2e,
         f"jax_cold_us={us_cold:.1f};"
         f"end_to_end_speedup={us_cold / us_e2e:.1f}x;"
         f"materialize_us={us_e2e - us_delta:.1f}")


# --- the 10k-event daemon stream ---------------------------------------------

def _daemon(n_jobs: int = 24, n_cfgs: int = 128, seed: int = 7
            ) -> SelectionDaemon:
    rng = np.random.default_rng(seed)
    ids = [f"cfg{i}" for i in range(n_cfgs)]
    store = ProfilingStore(config_ids=ids)
    for j in range(n_jobs):
        klass = JobClass.A if j % 2 else JobClass.B
        for c in range(n_cfgs):
            if rng.random() < 0.2:
                continue                      # partial profiling
            store.add(f"job{j}", ids[c], float(rng.uniform(0.1, 5.0)),
                      job_class=klass, group=f"g{j % 6}")
    table = PriceTable({c: float(rng.uniform(1.0, 30.0)) for c in ids})
    service = SelectionService(IdentityCatalog(ids), store, table)
    feed = SimulatedSpotFeed(dict(table.items()), seed=seed,
                             change_fraction=0.01)
    return SelectionDaemon(service, feed)


def bench_daemon(n_events: int = 10_000, seed: int = 7) -> None:
    daemon = _daemon(seed=seed)
    jobs = daemon.service.store.job_ids
    t0 = time.perf_counter()
    stats = daemon.run(synthetic_stream(jobs, n_events, seed=seed))
    dt = time.perf_counter() - t0
    # determinism: a fresh universe + the same seed => byte-identical journal
    again = _daemon(seed=seed)
    again.run(synthetic_stream(jobs, n_events, seed=seed))
    deterministic = again.journal_dump() == daemon.journal_dump()
    svc = daemon.service
    hit_rate = svc.cache_hits / max(1, svc.cache_hits + svc.cache_misses)
    emit(f"daemon_{n_events}ev", dt / n_events * 1e6,
         f"events_per_s={n_events / dt:.0f};decisions={stats.decisions};"
         f"ticks={stats.ticks};epochs={stats.epochs};"
         f"deltas={stats.deltas};cache_hit_rate={hit_rate:.3f};"
         f"incremental_refreshes={svc.reprice_refreshes};"
         f"deterministic={deterministic}")


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    bench_reprice(64, 1_000, 0.01)
    bench_reprice(64, 10_000, 0.01)
    bench_reprice_jax(64, 10_000, 0.01)
    if not smoke:
        bench_reprice(64, 10_000, 0.001)
        bench_reprice(256, 10_000, 0.01)
        bench_reprice_jax(64, 10_000, 0.001)
    bench_daemon(2_000 if smoke else 10_000)
    write_json()


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
