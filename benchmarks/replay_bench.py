"""Replay-harness benchmarks: recorded feeds + journal audit + dynamic eval.

    PYTHONPATH=src python benchmarks/replay_bench.py [--smoke]

Three claims are enforced (ISSUE 3 acceptance):

  * **record/replay round-trip**: capturing a ``SimulatedSpotFeed`` with
    ``record_feed`` and replaying it through ``RecordedPriceFeed``
    reproduces the identical tick stream, and re-recording the recording
    reproduces the CSV *bytes*;
  * **journal audit**: every decision journaled by a daemon run over the
    recorded history is bit-identical to a cold ``rank_dense`` at its
    reconstructed price epoch — any mismatch fails the process (exit 1),
    which is what lets CI gate on the audit;
  * **dynamic evaluation**: the replayed history yields a
    deviation-from-optimal report (realized vs per-epoch oracle vs
    static-price oracle) — the paper's Fig. 2 metric under moving prices.

Smoke mode replays the bundled ``examples/data/gcp_spot_prices.csv``
fixture over the paper universe; full mode additionally records and
replays a 10x larger synthetic universe.  Rows are written to
``BENCH_replay.json`` (override with ``BENCH_REPLAY_JSON``).
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from _bench_io import BenchRows
from repro.core import costmodel, spark_sim
from repro.core.trace import JobClass
from repro.market import (JournalReplayer, RecordedPriceFeed,
                          SelectionDaemon, SimulatedSpotFeed, record_feed,
                          synthetic_stream)
from repro.selector import (GcpVmCatalog, IdentityCatalog, PriceTable,
                            ProfilingStore, SelectionService)

ROWS = BenchRows("BENCH_REPLAY_JSON", "BENCH_replay.json")
emit = ROWS.emit
write_json = ROWS.write_json

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "examples", "data", "gcp_spot_prices.csv")


def _paper_daemon(feed) -> SelectionDaemon:
    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    service = SelectionService(catalog, store,
                               PriceTable.from_catalog(catalog))
    return SelectionDaemon(service, feed)


def _synth_service(n_jobs: int, n_cfgs: int, seed: int = 7
                   ) -> SelectionService:
    """A universe with the paper's structure: runtimes factor into
    per-class config affinity x per-job scale x mild noise, so class-mates
    actually predict a submitted job's behaviour (uncorrelated random
    runtimes would make any deviation metric measure noise, not the
    harness)."""
    rng = np.random.default_rng(seed)
    ids = [f"cfg{i}" for i in range(n_cfgs)]
    speed = {JobClass.A: rng.uniform(0.5, 3.0, n_cfgs),
             JobClass.B: rng.uniform(0.5, 3.0, n_cfgs)}
    store = ProfilingStore(config_ids=ids)
    for j in range(n_jobs):
        klass = JobClass.A if j % 2 else JobClass.B
        scale = rng.uniform(0.2, 2.0)
        for c in range(n_cfgs):
            if rng.random() < 0.2:
                continue                      # partial profiling
            hours = scale * speed[klass][c] * rng.lognormal(0.0, 0.08)
            store.add(f"job{j}", ids[c], float(hours),
                      job_class=klass, group=f"g{j % 6}")
    table = PriceTable({c: float(rng.uniform(1.0, 30.0)) for c in ids})
    return SelectionService(IdentityCatalog(ids), store, table)


def bench_record_roundtrip(n_cfgs: int = 256, ticks: int = 200,
                           seed: int = 5) -> None:
    rng = np.random.default_rng(seed)
    base = {f"c{i}": float(rng.uniform(0.5, 20.0)) for i in range(n_cfgs)}
    t0 = time.perf_counter()
    text = record_feed(SimulatedSpotFeed(base, seed=seed,
                                         change_fraction=0.05), ticks)
    us_record = (time.perf_counter() - t0) / ticks * 1e6
    t0 = time.perf_counter()
    feed = RecordedPriceFeed.loads(text)
    us_load = (time.perf_counter() - t0) * 1e6
    # replaying the replay is the identity on the bytes
    identical = record_feed(feed, ticks) == text
    # and the recording equals a fresh same-seed simulation, batch for batch
    fresh = SimulatedSpotFeed(base, seed=seed, change_fraction=0.05)
    matches = all(feed.poll(t) == fresh.poll(t) for t in range(ticks))
    emit(f"record_roundtrip_{n_cfgs}x{ticks}t", us_record,
         f"bytes={len(text)};load_us={us_load:.1f};"
         f"rerecord_byte_identical={identical};"
         f"matches_fresh_sim={matches}")
    if not (identical and matches):
        raise SystemExit("record/replay round-trip violated")


def bench_journal_audit(daemon: SelectionDaemon, n_events: int, seed: int,
                        label: str, job_ids=None) -> None:
    jobs = job_ids if job_ids is not None else daemon.service.store.job_ids
    daemon.run(synthetic_stream(jobs, n_events, seed=seed,
                                tick_fraction=0.15))
    journal = daemon.journal_dump()
    replayer = JournalReplayer(daemon.service.store, journal)
    t0 = time.perf_counter()
    audit = replayer.audit()
    dt = time.perf_counter() - t0
    emit(f"journal_audit_{label}", dt / max(1, audit.decisions) * 1e6,
         f"decisions={audit.decisions};ticks={audit.ticks};"
         f"rejected={audit.rejected};mismatches={len(audit.mismatches)};"
         f"journal_bytes={len(journal)}")
    if not audit.ok:
        for m in audit.mismatches[:5]:
            print(f"MISMATCH seq={m.seq} job={m.job_id} field={m.field} "
                  f"journaled={m.journaled!r} replayed={m.replayed!r}",
                  file=sys.stderr)
        raise SystemExit(
            f"journal audit failed: {len(audit.mismatches)} mismatches")

    t0 = time.perf_counter()
    ev = replayer.evaluate()
    dt = time.perf_counter() - t0
    emit(f"dynamic_eval_{label}", dt * 1e6,
         f"mean_deviation={ev.mean_deviation:.4f};"
         f"max_deviation={ev.max_deviation:.4f};"
         f"static_mean_deviation={ev.static_mean_deviation:.4f};"
         f"skipped={ev.skipped};"
         f"beats_static={ev.mean_deviation < ev.static_mean_deviation}")


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    bench_record_roundtrip(64 if smoke else 256, 50 if smoke else 200)

    # the bundled fixture over the paper universe (the CI smoke)
    trace_jobs = [j.name for j in spark_sim.generate_trace(seed=0).jobs]
    daemon = _paper_daemon(RecordedPriceFeed.load(FIXTURE))
    bench_journal_audit(daemon, 400, seed=3, label="paper_fixture",
                        job_ids=trace_jobs)

    if not smoke:
        svc = _synth_service(24, 1_000)
        feed = RecordedPriceFeed.loads(record_feed(
            SimulatedSpotFeed(dict(svc.price_source.items()), seed=7,
                              change_fraction=0.01), 400))
        bench_journal_audit(SelectionDaemon(svc, feed), 3_000, seed=7,
                            label="synth_24x1000")
    write_json()


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
