"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSON.

    PYTHONPATH=src python -m benchmarks.roofline dryrun_single.json
"""
from __future__ import annotations

import json
import sys

from repro.launch.roofline import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16


def fmt_bytes(x):
    return f"{x/1e9:.2f}GB" if x >= 1e9 else f"{x/1e6:.1f}MB"


def render(path: str) -> str:
    with open(path) as f:
        report = json.load(f)
    rows = []
    header = ("| arch | shape | mesh | compute_s | memory_s | collective_s |"
              " dominant | roofline_frac | useful_ratio | peak_HBM/dev |")
    sep = "|" + "---|" * 10
    rows.append(header)
    rows.append(sep)
    for c in report["cells"]:
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — |"
                        f" — | skipped | — | — | — |")
            continue
        if not c.get("ok") or "roofline" not in c:
            status = "FAILED" if not c.get("ok") else "no-analysis"
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — |"
                        f" — | {status} | — | — | — |")
            continue
        r = c["roofline"]
        step = r["step_s"]
        # roofline fraction: useful model compute time / bound step time
        model_t = c.get("model_flops_per_device", 0) / PEAK_FLOPS_BF16
        frac = model_t / step if step else 0.0
        ur = c.get("useful_flops_ratio")
        ur_s = f"{ur:.3f}" if ur is not None else "—"
        mem = c.get("memory", {}).get("peak_bytes_per_device")
        mem_s = fmt_bytes(mem) if mem else "—"
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} |"
            f" {r['compute_s']:.4f} | {r['memory_s']:.4f} |"
            f" {r['collective_s']:.4f} | {r['dominant']} |"
            f" {frac:.3f} | {ur_s} | {mem_s} |")
    return "\n".join(rows)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_single.json"
    print(render(path))


if __name__ == "__main__":
    main()
