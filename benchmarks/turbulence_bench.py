"""Turbulence-sweep benchmark: deviation-vs-turbulence curves, gated.

    PYTHONPATH=src python benchmarks/turbulence_bench.py [--smoke]

Runs the `repro.market.turbulence` sweep driver over the turbulence
preset grid for every available backend and emits the deviation-vs-
turbulence curve to ``BENCH_turbulence.json`` (override with
``BENCH_TURBULENCE_JSON``).  Four claims are gated — any failure exits
nonzero, which is what lets CI block on them (ISSUE 10 acceptance):

  * **fixture regeneration**: the ``calm`` preset regenerates the
    bundled ``examples/data/gcp_spot_prices.csv`` byte-for-byte
    (generator drift would silently re-baseline every figure);
  * **baseline deviation**: the calm point over the bundled fixture on
    the numpy backend keeps mean deviation <= the recorded 6.4%
    figure (``BASELINE_MEAN_DEVIATION``) — and, the feed being
    unlagged, its truth-judged deviation equals the journal-judged one
    exactly;
  * **audit**: every sweep point's journal passes
    ``JournalReplayer.audit`` under its backend's ScoreContract — a
    point whose audit failed is not evidence about the selector;
  * **polled == recorded**: the identical sweep code path over a
    ``RecordedPriceFeed`` fixture and a stubbed ``PollingPriceFeed``
    serving the same quotes produces identical evaluations.

Smoke mode (the CI ``turbulence`` job) runs the 2x2 grid
``(calm, eviction_storm) x (numpy, jax_batched)``; full mode runs all
presets x all available backends.  Each sweep row carries its full
``TurbulencePoint.summary()`` under a JSON-only ``point`` key, and the
per-backend ``turbulence_curve_*`` rows carry the level-ordered curve
under ``curve`` — the machine-readable deviation-vs-turbulence artifact
(DESIGN.md §15).
"""
from __future__ import annotations

import os
import sys
import time

from _bench_io import BenchRows, Gates, check_gates
from repro.core import costmodel, spark_sim
from repro.core.evaluate import turbulence_curves
from repro.market import (PollingPriceFeed, RecordedPriceFeed,
                          TURBULENCE_PRESETS, make_market, record_feed,
                          run_point, run_sweep, synthetic_stream)
from repro.obs import SWEEP_SPAN
from repro.selector import (BACKENDS, GcpVmCatalog, PriceTable,
                            ProfilingStore, SelectionService,
                            backend_available)

ROWS = BenchRows("BENCH_TURBULENCE_JSON", "BENCH_turbulence.json")
emit = ROWS.emit
write_json = ROWS.write_json

#: gated claims that failed this run; main() exits nonzero on any.
GATES = Gates()
gate = GATES.gate

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "examples", "data", "gcp_spot_prices.csv")

#: the recorded calm-regime figure: mean deviation from the per-epoch
#: cost oracle over the bundled fixture on numpy (6.4%, the live-market
#: analogue of the paper's Fig. 2 claim, DESIGN.md §8).  Measured
#: 0.064462; the calm baseline point regressing past this fails CI.
BASELINE_MEAN_DEVIATION = 0.0645

#: the CI smoke grid (2 presets x 2 backends).
SMOKE_PRESETS = ("calm", "eviction_storm")
SMOKE_BACKENDS = ("numpy", "jax_batched")

#: the shared daemon stream: same submissions hit every sweep cell.
N_EVENTS = 400
STREAM_SEED = 3
MARKET_SEED = 11


def _universe():
    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    jobs = [j.name for j in trace.jobs]
    return catalog, store, jobs


def _derived(point) -> str:
    truth = point.truth_mean_deviation
    return (f"preset={point.preset};level={point.level:g};"
            f"backend={point.backend};feed={point.feed_kind};"
            f"mean_deviation={point.mean_deviation:.4f};"
            f"truth_mean_deviation={truth:.4f};"
            f"audit_ok={point.audit_ok};drift={point.audit_drift};"
            f"decisions={point.decisions};epochs={point.epochs}")


def bench_fixture_regen(base) -> None:
    """Gate: calm preset => the bundled fixture, byte for byte."""
    with open(FIXTURE) as f:
        fixture_text = f.read()
    t0 = time.perf_counter()
    market = make_market("calm", base, seed=MARKET_SEED, ticks=40)
    regen = record_feed(market.raw, 40)
    us = (time.perf_counter() - t0) / 40 * 1e6
    identical = regen == fixture_text
    emit("turbulence_calm_fixture_regen", us,
         f"byte_identical={identical};bytes={len(regen)};"
         f"events={len(market.events)}")
    gate("turbulence_calm_fixture_regen",
         "calm preset regenerates gcp_spot_prices.csv byte-identical",
         identical)


def bench_baseline(catalog, store, events) -> None:
    """Gate: the recorded 6.4% calm figure over the bundled fixture."""
    service = SelectionService(catalog, store,
                               PriceTable.from_catalog(catalog))
    t0 = time.perf_counter()
    point = run_point(service, RecordedPriceFeed.load(FIXTURE), events,
                      preset_name="calm", level=0.0, feed_kind="recorded",
                      truth=RecordedPriceFeed.load(FIXTURE))
    us = (time.perf_counter() - t0) / max(1, point.decisions) * 1e6
    emit("turbulence_baseline_fixture_numpy", us, _derived(point),
         point=point.summary())
    gate("turbulence_baseline_fixture_numpy",
         f"mean deviation {point.mean_deviation:.4f} <= recorded "
         f"baseline {BASELINE_MEAN_DEVIATION}",
         point.mean_deviation <= BASELINE_MEAN_DEVIATION)
    gate("turbulence_baseline_fixture_numpy", "journal audit passes",
         point.audit_ok)
    gate("turbulence_baseline_fixture_numpy",
         "truth judge == journal judge on an unlagged feed",
         point.truth_mean_deviation == point.mean_deviation)


def bench_sweep(catalog, store, base, events, smoke: bool) -> None:
    """The grid: every preset x every available backend, all gated on
    audit; per-backend curves emitted as the JSON artifact."""
    presets = list(SMOKE_PRESETS) if smoke else [
        p.name for p in sorted(TURBULENCE_PRESETS.values(),
                               key=lambda q: q.level)]
    wanted = SMOKE_BACKENDS if smoke else BACKENDS
    backends = [b for b in wanted if backend_available(b)]
    for b in wanted:
        if b not in backends:
            print(f"# skipping backend {b}: unavailable", file=sys.stderr)

    services = []

    def factory(backend: str) -> SelectionService:
        svc = SelectionService(catalog, store,
                               PriceTable.from_catalog(catalog),
                               backend=backend)
        services.append(svc)
        return svc

    points = run_sweep(factory, base, events, presets=presets,
                       backends=backends, seed=MARKET_SEED)
    for svc, point in zip(services, points):
        secs = svc.metrics.histogram(SWEEP_SPAN).sum
        emit(f"turbulence_{point.preset}_{point.backend}",
             secs / max(1, point.decisions) * 1e6, _derived(point),
             point=point.summary())
        gate(f"turbulence_{point.preset}_{point.backend}",
             "sweep journal passes audit under the backend contract",
             point.audit_ok)

    for backend, curve in turbulence_curves(points).items():
        total = sum(s.metrics.histogram(SWEEP_SPAN).sum
                    for s, p in zip(services, points)
                    if p.backend == backend)
        devs = ";".join(f"{p.preset}={p.mean_deviation:.4f}"
                        for p in curve)
        emit(f"turbulence_curve_{backend}", total * 1e6,
             f"points={len(curve)};{devs}",
             curve=[p.summary() for p in curve])


def bench_polled_vs_recorded(catalog, store, base, events) -> None:
    """Gate: one quote stream, two transports, identical curves."""
    ticks = sum(1 for e in events
                if type(e).__name__ == "Tick") or 40
    market = make_market("eviction_storm", base, seed=MARKET_SEED,
                         ticks=ticks)
    text = record_feed(market.raw, ticks)

    def fresh():
        return SelectionService(catalog, store,
                                PriceTable.from_catalog(catalog))

    recorded = run_point(fresh(), RecordedPriceFeed.loads(text), events,
                         preset_name="eviction_storm", level=3.0,
                         feed_kind="recorded",
                         truth=RecordedPriceFeed.loads(text))

    replay = RecordedPriceFeed.loads(text)
    polling = PollingPriceFeed(lambda t: {"quotes": [
        {"config_id": d.config_id, "price": d.price}
        for d in replay.poll(t)]})
    polled = run_point(fresh(), polling, events,
                       preset_name="eviction_storm", level=3.0,
                       feed_kind="polled",
                       truth=RecordedPriceFeed.loads(text))

    identical = (recorded.evaluation.summary() ==
                 polled.evaluation.summary() and
                 recorded.mean_deviation == polled.mean_deviation and
                 recorded.decisions == polled.decisions and
                 recorded.epochs == polled.epochs)
    emit("turbulence_polled_vs_recorded", 0.0,
         f"identical={identical};polls={polling.polls};"
         f"recorded_dev={recorded.mean_deviation:.4f};"
         f"polled_dev={polled.mean_deviation:.4f}",
         recorded=recorded.summary(), polled=polled.summary())
    gate("turbulence_polled_vs_recorded",
         "identical quote stream over PollingPriceFeed reproduces the "
         "RecordedPriceFeed curve exactly",
         identical and recorded.audit_ok and polled.audit_ok)


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    catalog, store, jobs = _universe()
    base = dict(PriceTable.from_catalog(catalog).items())
    events = list(synthetic_stream(jobs, N_EVENTS, seed=STREAM_SEED,
                                   tick_fraction=0.15))

    bench_fixture_regen(base)
    bench_baseline(catalog, store, events)
    bench_sweep(catalog, store, base, events, smoke)
    bench_polled_vs_recorded(catalog, store, base, events)

    write_json()
    check_gates(GATES.failures)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
