"""Concurrent serving benchmark: the front-end vs the single-thread daemon.

    PYTHONPATH=src python benchmarks/serve_bench.py

The claim under test (ISSUE 6 acceptance — the script exits nonzero when
a gated claim regresses, which is the CI gate): with per-decision reply
latency on the serving path (the client round-trip a real deployment
pays; modeled as a 1 ms ``on_decision`` sleep), the snapshot-serving
front-end at 4 workers sustains **>=3x** the submission throughput of
the single-threaded :class:`~repro.market.SelectionDaemon` over the
*same recorded market*, and worker scaling from 1 to 4 stays near-linear
(parallel efficiency >= 0.7).  Both are honest under the GIL because the
hot path is latency-bound, not compute-bound: workers overlap their
reply waits while the tick thread keeps repricing.

Correctness is gated alongside throughput, not assumed: every front-end
leg must account for all submissions (zero shed at benchmark capacity,
accepted = journaled) and its merged journal must pass
``JournalReplayer.audit`` — byte-exact on numpy; within the
ScoreContract on the jax_batched leg (skipped when jax is absent).

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows as
machine-readable ``BENCH_serve.json`` (override the path with the
``BENCH_SERVE_JSON`` env var) so CI can track the perf trajectory.
"""
from __future__ import annotations

import sys
import time

from _bench_io import BenchRows, Gates, check_gates
from repro.core.trace import JobClass
from repro.market import (JournalReplayer, RecordedPriceFeed,
                          SelectionDaemon, ServeFrontend, SimulatedSpotFeed,
                          Submission, Tick, record_feed)
from repro.selector import (IdentityCatalog, PriceTable, ProfilingStore,
                            SelectionService, backend_available)

ROWS = BenchRows("BENCH_SERVE_JSON", "BENCH_serve.json")
emit = ROWS.emit
write_json = ROWS.write_json

#: gated claims that failed this run; main() exits nonzero on any.
GATES = Gates()
gate = GATES.gate

#: modeled client-reply latency per served decision (seconds).
LATENCY = 0.001

N_JOBS = 12
N_CFGS = 24

#: six distinct (class, exclusion) selections — the live fleet.
SELECTIONS = [
    ("j1", None), ("j2", None), ("j3", None), ("j4", None),
    ("j1", ("g2", "g3")), ("j2", ("g1",)),
]


# --- the shared recorded market + submission load -----------------------------

def _universe():
    ids = [f"c{i}" for i in range(N_CFGS)]
    store = ProfilingStore(config_ids=ids)
    for j in range(N_JOBS):
        klass = JobClass.A if j % 2 else JobClass.B
        for i, c in enumerate(ids):
            store.add(f"j{j}", c,
                      0.1 + ((j * 13 + i * 7) % 29) / 8.0
                      + (0.5 if klass is JobClass.A and i % 3 == 0
                         else 0.0),
                      job_class=klass, group=f"g{j % 4}")
    base = {c: 1.0 + (i * 11 % 17) for i, c in enumerate(ids)}
    return store, ids, base


def _market_text(base, n_ticks: int) -> str:
    sim = SimulatedSpotFeed(base, seed=42, change_fraction=0.5,
                            volatility=0.08)
    return record_feed(sim, n_ticks)


def _submissions(n: int) -> "list[Submission]":
    return [Submission(job, exclude_groups=excl)
            for job, excl in (SELECTIONS[i % len(SELECTIONS)]
                              for i in range(n))]


def _service(store, ids, base, backend="numpy",
             serve_top_k=None) -> SelectionService:
    return SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                            backend=backend, serve_top_k=serve_top_k)


# --- the single-threaded baseline ---------------------------------------------

def bench_daemon(store, ids, base, market: str, subs, n_ticks: int) -> float:
    """One thread serializes everything: ticks, decisions, and the
    per-decision reply wait.  Returns submissions/second."""
    svc = _service(store, ids, base)
    daemon = SelectionDaemon(svc, RecordedPriceFeed.loads(market))
    every = max(1, len(subs) // n_ticks)
    t0 = time.perf_counter()
    ticked = 0
    for i, sub in enumerate(subs):
        if ticked < n_ticks and i % every == 0:
            daemon.handle(Tick())
            ticked += 1
        decision = daemon.handle(sub)
        if decision is not None:
            time.sleep(LATENCY)              # the inline client reply
    while ticked < n_ticks:
        daemon.handle(Tick())
        ticked += 1
    dt = time.perf_counter() - t0
    audit = JournalReplayer(store, daemon.journal_dump()).audit()
    tput = len(subs) / dt
    emit("serve_daemon_1thread", dt / len(subs) * 1e6,
         f"subs={len(subs)};ticks={n_ticks};tput_per_s={tput:.0f};"
         f"latency_ms={LATENCY * 1e3:g};audit_ok={audit.ok}")
    gate("serve_daemon_1thread", "journal audits clean", audit.ok)
    return tput


# --- the front-end legs -------------------------------------------------------

def bench_frontend(store, ids, base, market: str, subs, workers: int,
                   backend: str = "numpy", baseline_tput: float = 0.0,
                   tput_1w: float = 0.0) -> float:
    """N workers overlap their reply waits off the latest snapshot while
    the tick thread replays the recorded market.  Returns
    submissions/second over the submit->drain window."""
    name = f"serve_frontend_{workers}w" + (
        "" if backend == "numpy" else f"_{backend}")
    if not backend_available(backend):
        emit(name, 0.0, "skipped=jax_unavailable")
        return 0.0
    svc = _service(store, ids, base, backend=backend,
                   serve_top_k=3 if backend == "jax_batched" else None)
    feed = RecordedPriceFeed.loads(market)
    fe = ServeFrontend(svc, feed, workers=workers,
                       queue_capacity=len(subs) + 1,
                       on_decision=lambda d: time.sleep(LATENCY))
    fe.warm(subs[:len(SELECTIONS)])
    with fe:
        t0 = time.perf_counter()
        for sub in subs:
            fe.submit(sub)
        fe.drain(timeout=120.0)
        dt = time.perf_counter() - t0
        fe.await_ticks(timeout=60.0)         # let the market finish
    stats = fe.stats()
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    accounted = stats.accounted and stats.shed == 0 \
        and stats.decisions == len(subs)
    tput = len(subs) / dt
    derived = (f"subs={len(subs)};workers={workers};"
               f"tput_per_s={tput:.0f};"
               f"speedup_vs_daemon={tput / baseline_tput:.2f}x;"
               f"accounted={accounted};audit_ok={audit.ok}")
    if tput_1w:
        eff = tput / (workers * tput_1w)
        derived += f";scaling_efficiency={eff:.2f}"
    emit(name, dt / len(subs) * 1e6, derived)
    gate(name, "all submissions accounted (zero shed, all journaled)",
         accounted)
    gate(name, "merged journal audits clean", audit.ok)
    return tput


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    n_subs, n_ticks = (240, 60) if smoke else (600, 220)
    store, ids, base = _universe()
    market = _market_text(base, n_ticks)
    subs = _submissions(n_subs)

    daemon_tput = bench_daemon(store, ids, base, market, subs, n_ticks)
    tput_1w = bench_frontend(store, ids, base, market, subs, 1,
                             baseline_tput=daemon_tput)
    bench_frontend(store, ids, base, market, subs, 2,
                   baseline_tput=daemon_tput, tput_1w=tput_1w)
    tput_4w = bench_frontend(store, ids, base, market, subs, 4,
                             baseline_tput=daemon_tput, tput_1w=tput_1w)

    # THE gated claims: >=3x the single-threaded daemon at 4 workers,
    # near-linear 1->4 worker scaling (the reply waits overlap; the
    # snapshot hot path adds no serialization of its own)
    speedup = tput_4w / daemon_tput if daemon_tput else 0.0
    gate("serve_frontend_4w",
         f"throughput >= 3x single-threaded daemon (got {speedup:.2f}x)",
         speedup >= 3.0)
    efficiency = tput_4w / (4 * tput_1w) if tput_1w else 0.0
    gate("serve_frontend_4w",
         f"1->4 worker scaling efficiency >= 0.7 (got {efficiency:.2f})",
         efficiency >= 0.7)

    # the batched-fleet leg: same shape, tolerance-audited (DESIGN.md §10)
    bench_frontend(store, ids, base, market, subs, 4,
                   backend="jax_batched", baseline_tput=daemon_tput,
                   tput_1w=tput_1w)

    write_json()
    check_gates(GATES.failures)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
