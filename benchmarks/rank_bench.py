"""Vectorized rank vs the historical per-pair dict loop.

    PYTHONPATH=src python benchmarks/rank_bench.py

Prints ``name,cells,us_dict,us_numpy,us_jax,speedup`` CSV rows.  The
acceptance bar: the vectorized formulation must beat the dict loop from
~1k (job x config) cells up (at 10k+ cells the ranking is one fused
matrix op instead of ~cells dict lookups).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Hashable, List, Mapping, Sequence, Tuple

import numpy as np

from repro.selector import BackendUnavailableError, rank_dense, rank_pairs


def rank_dict_loop(
    runtime_hours: Mapping[Tuple[Hashable, Hashable], float],
    jobs: Sequence[Hashable],
    config_ids: Sequence[Hashable],
    hourly_cost: Callable[[Hashable], float],
) -> List[Tuple[Hashable, float]]:
    """The pre-selector implementation, kept verbatim as the baseline."""
    scores: Dict[Hashable, float] = {c: 0.0 for c in config_ids}
    for j in jobs:
        costs = {c: runtime_hours[(j, c)] * hourly_cost(c)
                 for c in config_ids if (j, c) in runtime_hours}
        if not costs:
            continue
        best = min(costs.values())
        for c, v in costs.items():
            scores[c] += v / best
    order = {c: i for i, c in enumerate(config_ids)}
    return sorted(scores.items(), key=lambda kv: (kv[1], order[kv[0]]))


def synth_universe(n_jobs: int, n_cfgs: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    jobs = [f"j{i}" for i in range(n_jobs)]
    cfgs = [f"c{i}" for i in range(n_cfgs)]
    hours = rng.uniform(0.05, 10.0, size=(n_jobs, n_cfgs))
    prices = rng.uniform(0.5, 20.0, size=n_cfgs)
    pairs = {(j, c): float(hours[r, k])
             for r, j in enumerate(jobs) for k, c in enumerate(cfgs)}
    return jobs, cfgs, hours, np.ones_like(hours, dtype=bool), prices, pairs


def _timed(fn, repeat: int) -> float:
    fn()                                    # warmup (jit compile, caches)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat * 1e6


def compare(n_jobs: int, n_cfgs: int, repeat: int = 20) -> Dict[str, float]:
    jobs, cfgs, hours, mask, prices, pairs = synth_universe(n_jobs, n_cfgs)
    price_of = dict(zip(cfgs, prices)).__getitem__
    us_dict = _timed(lambda: rank_dict_loop(pairs, jobs, cfgs, price_of),
                     repeat)
    us_numpy = _timed(lambda: rank_dense(hours, mask, prices, cfgs), repeat)
    try:
        us_jax = _timed(lambda: rank_dense(hours, mask, prices, cfgs,
                                           backend="jax"), repeat)
    except BackendUnavailableError:
        us_jax = float("nan")
    # sanity: identical winner and ordering
    base = [c for c, _ in rank_dict_loop(pairs, jobs, cfgs, price_of)]
    vec = [r.config_id for r in rank_pairs(pairs, jobs, cfgs, price_of)]
    assert base == vec, "vectorized ranking diverged from the dict loop"
    return {"cells": n_jobs * n_cfgs, "us_dict": us_dict,
            "us_numpy": us_numpy, "us_jax": us_jax,
            "speedup": us_dict / us_numpy}


def main() -> None:
    print("name,cells,us_dict,us_numpy,us_jax,speedup")
    for n_jobs, n_cfgs in ((10, 10), (50, 20), (100, 100), (500, 100),
                           (1000, 250)):
        r = compare(n_jobs, n_cfgs, repeat=5 if n_jobs >= 500 else 20)
        print(f"rank_{n_jobs}x{n_cfgs},{r['cells']},{r['us_dict']:.1f},"
              f"{r['us_numpy']:.1f},{r['us_jax']:.1f},{r['speedup']:.1f}x")


if __name__ == "__main__":
    main()
