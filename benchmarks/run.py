"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows: ``us_per_call`` is the wall
time of one full experiment computation (the paper's headline claim is that
Flora's *selection overhead is negligible* — milliseconds); ``derived`` is
the experiment's headline number(s).  The same rows are written as
machine-readable ``BENCH_selector.json`` (override the path with the
``BENCH_SELECTOR_JSON`` env var) so CI can track the perf trajectory.
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import costmodel, evaluate, spark_sim
from repro.core.flora import Flora
from repro.core.trace import JobClass, PAPER_JOBS

from _bench_io import BenchRows

ROWS = BenchRows("BENCH_SELECTOR_JSON", "BENCH_selector.json")
emit = ROWS.emit
write_json = ROWS.write_json



def _timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def bench_table3_trace_stats(trace, price):
    stats, us = _timed(trace.stats, price)
    derived = (f"cost_mean={stats['cost_usd']['mean']:.3f};"
               f"rt_mean={stats['runtime_s']['mean']:.0f};"
               f"rt_max={stats['runtime_s']['max']:.0f}"
               f" (paper: 1.409/1835/21715)")
    emit("table3_trace_stats", us, derived)


def bench_table4_selection(trace, price):
    results, us = _timed(evaluate.table4, trace, price)
    by = {r.name: r for r in results}
    derived = ";".join(
        f"{name}={by[name].mean_norm_cost:.3f}"
        for name in ("Flora", "Flora with one class", "Juggler", "Crispy"))
    derived += " (paper: Flora=1.052;Fw1C=1.336;Juggler=1.334;Crispy=1.384)"
    emit("table4_selection", us, derived)


def bench_table5_perjob(trace, price):
    t5, us = _timed(evaluate.table5, trace, price)
    flora = t5["Flora"]
    worst = max(r.norm_cost for r in flora.per_job)
    a_picks = {r.selection.index for r in flora.per_job
               if r.job.job_class is JobClass.A}
    b_picks = {r.selection.index for r in flora.per_job
               if r.job.job_class is JobClass.B}
    derived = (f"flora_mean={flora.mean_norm_cost:.3f};max={worst:.3f};"
               f"classA_picks={sorted(a_picks)};classB_picks={sorted(b_picks)}"
               f" (paper: A->9, B->1, mean 1.052)")
    emit("table5_perjob", us, derived)


def bench_fig2_price_sweep(trace, price):
    ratios = [10 ** (-2 + 3 * i / 24) for i in range(25)]
    curves, us = _timed(evaluate.fig2_price_sweep, trace, price, ratios)
    always_best = all(
        curves["Flora"][i] <= min(v[i] for k, v in curves.items()
                                  if k != "Flora") + 1e-9
        for i in range(len(ratios)))
    derived = (f"points={len(ratios)};"
               f"flora_max_over_sweep={max(curves['Flora']):.3f};"
               f"flora_always_best={always_best}")
    emit("fig2_price_sweep", us, derived)


def bench_fig3_misclassification(trace, price):
    fracs = [i / 20 for i in range(21)]
    curves, us = _timed(evaluate.fig3_misclassification, trace, price, fracs)
    x, us2 = _timed(evaluate.crossover_fraction, trace, price)
    derived = (f"crossover_vs_fw1c={x:.3f} (paper: ~1/3);"
               f"coinflip={curves['Flora'][10]:.3f};"
               f"random={curves['random selection'][0]:.3f}")
    emit("fig3_misclassification", us + us2, derived)


def bench_selection_overhead(trace, price):
    """§III-B: per-selection overhead 'in the millisecond range'."""
    flora = Flora(trace, price)
    job = PAPER_JOBS[0]
    _, us = _timed(lambda: flora.select_for_job(job), repeat=200)
    emit("selection_overhead", us,
         f"paper_claims_milliseconds={us < 10_000}")


def bench_tpu_selection():
    """DESIGN.md §3: mesh selection over the dry-run-profiled trace."""
    from repro.core.costmodel import TpuPriceModel
    from repro.core.tpu_flora import service_from_dryrun_report
    path = os.environ.get("DRYRUN_REPORT", "dryrun_single.json")
    if not os.path.exists(path):
        emit("tpu_selection", 0.0, "skipped=no_dryrun_report")
        return
    with open(path) as f:
        report = json.load(f)
    service = service_from_dryrun_report(report, TpuPriceModel())
    if not len(service.store) or not len(service.catalog):
        emit("tpu_selection", 0.0, "skipped=empty_report")
        return
    pick, us = _timed(lambda: service.submit("decode_32k"))
    emit("tpu_selection", us,
         f"decode_pick={pick.config_id};records={len(service.store)};"
         f"cached={pick.from_cache}")


def bench_rank_vectorized_vs_dict():
    """Tentpole acceptance: vectorized rank beats the per-pair dict loop
    from ~1k (job x config) cells (see benchmarks/rank_bench.py for the
    full sweep)."""
    import rank_bench
    for n_jobs, n_cfgs in ((50, 20), (200, 50)):
        r = rank_bench.compare(n_jobs, n_cfgs, repeat=10)
        emit(f"rank_vectorized_{n_jobs}x{n_cfgs}", r["us_numpy"],
             f"cells={r['cells']};dict_loop_us={r['us_dict']:.1f};"
             f"speedup={r['speedup']:.1f}x;"
             f"vectorized_wins={r['us_numpy'] < r['us_dict']}")


def main() -> None:
    t0 = time.time()
    trace = spark_sim.generate_trace(seed=0)
    price = costmodel.LinearPriceModel()
    print("name,us_per_call,derived")
    bench_table3_trace_stats(trace, price)
    bench_table4_selection(trace, price)
    bench_table5_perjob(trace, price)
    bench_fig2_price_sweep(trace, price)
    bench_fig3_misclassification(trace, price)
    bench_selection_overhead(trace, price)
    bench_tpu_selection()
    bench_rank_vectorized_vs_dict()
    write_json()
    if "--with-replay" in sys.argv:
        # the dynamic-price counterpart of the Fig. 2 rows above: replay
        # the bundled recorded history, audit the journal, score vs the
        # oracles (writes its own BENCH_replay.json; exits 1 on mismatch)
        import replay_bench
        replay_bench.main(smoke=True)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
